//! Finite-difference checks of the native backward (conv, BN, FC,
//! quantizer STEs) and a training smoke test on the native backend.
//!
//! Quantizer rounds are straight-through estimators, so their gradients
//! are checked against the *smooth STE surrogate* (round removed, scale s
//! frozen — exactly what the backward claims to differentiate), computed
//! in f64 inside the test.  Differentiable ops (conv, BN, pooling chains)
//! are checked against their actual forward.  Acceptance bar: ≤ 1e-2
//! relative error per sampled coordinate.

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::synth;
use pim_qat::nn::grad;
use pim_qat::nn::ExecSpec;
use pim_qat::pim::QuantBits;
use pim_qat::runtime::Manifest;
use pim_qat::tensor::arena::BufPool;
use pim_qat::tensor::gemm::{gemm, gemm_nt, gemm_tn};
use pim_qat::tensor::Tensor;
use pim_qat::train::native::run_job_native;
use pim_qat::train::network_from_ckpt;
use pim_qat::util::rng::Rng;

/// allclose with 1e-2 relative tolerance (the acceptance bar) plus a small
/// absolute floor for near-zero coordinates, where f32 forward roundoff
/// dominates the finite difference.
fn assert_close(fd: f64, analytic: f64, what: &str) {
    let tol = 1e-2 * fd.abs().max(analytic.abs()) + 5e-3;
    assert!(
        (fd - analytic).abs() <= tol,
        "{what}: fd {fd} vs analytic {analytic}"
    );
}

fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.normal_in(0.0, std)).collect())
}

/// ⟨G, y⟩ in f64.
fn dot_loss(g: &Tensor, y: &Tensor) -> f64 {
    g.data.iter().zip(&y.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
}

#[test]
fn conv_backward_matches_finite_difference() {
    let mut rng = Rng::new(41);
    for &(h, c, o, k, s) in &[(5usize, 3usize, 4usize, 3usize, 1usize), (6, 4, 3, 3, 2)] {
        let mut pool = BufPool::new();
        let x = randn(&[2, h, h, c], 1.0, &mut rng);
        let wcols = randn(&[c * k * k, o], 0.5, &mut rng);
        let (y, ctx) = grad::conv_cols_fwd(&x, &wcols, k, s, &mut pool);
        let g = randn(&y.shape, 1.0, &mut rng);
        let mut dwv = Vec::new();
        let dx = grad::conv_cols_bwd(&ctx, &wcols, &x.shape, k, s, &g.data, &mut pool, &mut dwv);
        let dw = Tensor::from_vec(&[c * k * k, o], dwv);

        let loss = |x: &Tensor, w: &Tensor| -> f64 {
            let mut pool = BufPool::new();
            let (y, _) = grad::conv_cols_fwd(x, w, k, s, &mut pool);
            dot_loss(&g, &y)
        };
        let eps = 1e-2f32;
        // sample input coordinates
        for t in 0..20 {
            let i = (t * 7919) % x.len();
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let fd = (loss(&xp, &wcols) - loss(&xm, &wcols)) / (2.0 * eps as f64);
            assert_close(fd, dx.data[i] as f64, &format!("conv dx[{i}] (k={k},s={s})"));
        }
        // sample weight coordinates
        for t in 0..20 {
            let i = (t * 104729) % wcols.len();
            let mut wp = wcols.clone();
            wp.data[i] += eps;
            let mut wm = wcols.clone();
            wm.data[i] -= eps;
            let fd = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * eps as f64);
            assert_close(fd, dw.data[i] as f64, &format!("conv dw[{i}] (k={k},s={s})"));
        }
    }
}

#[test]
fn bn_backward_matches_finite_difference() {
    let mut rng = Rng::new(42);
    let x = randn(&[2, 4, 4, 3], 1.5, &mut rng);
    let gamma: Vec<f32> = vec![1.2, 0.8, 1.5];
    let beta: Vec<f32> = vec![0.1, -0.3, 0.2];
    let (y, ctx) = grad::bn_train_fwd(&x, &gamma, &beta);
    let g = randn(&y.shape, 1.0, &mut rng);
    let (dx, dgamma, dbeta) = grad::bn_train_bwd(&ctx, &gamma, &g);

    let loss = |x: &Tensor, gamma: &[f32], beta: &[f32]| -> f64 {
        let (y, _) = grad::bn_train_fwd(x, gamma, beta);
        dot_loss(&g, &y)
    };
    let eps = 3e-3f32;
    for t in 0..24 {
        let i = (t * 7919) % x.len();
        let mut xp = x.clone();
        xp.data[i] += eps;
        let mut xm = x.clone();
        xm.data[i] -= eps;
        let fd = (loss(&xp, &gamma, &beta) - loss(&xm, &gamma, &beta)) / (2.0 * eps as f64);
        assert_close(fd, dx.data[i] as f64, &format!("bn dx[{i}]"));
    }
    for ci in 0..3 {
        let mut gp = gamma.clone();
        gp[ci] += eps;
        let mut gm = gamma.clone();
        gm[ci] -= eps;
        let fd = (loss(&x, &gp, &beta) - loss(&x, &gm, &beta)) / (2.0 * eps as f64);
        assert_close(fd, dgamma[ci] as f64, &format!("bn dgamma[{ci}]"));

        let mut bp = beta.clone();
        bp[ci] += eps;
        let mut bm = beta.clone();
        bm[ci] -= eps;
        let fd = (loss(&x, &gamma, &bp) - loss(&x, &gamma, &bm)) / (2.0 * eps as f64);
        assert_close(fd, dbeta[ci] as f64, &format!("bn dbeta[{ci}]"));
    }
}

/// The STE surrogate of the weight quantizer: tanh(w)/D(w) with the round
/// removed (what the backward claims to differentiate), in f64.
fn wq_surrogate_loss(w: &Tensor, g_q: &Tensor) -> f64 {
    let t: Vec<f64> = w.data.iter().map(|&v| (v as f64).tanh()).collect();
    let d = t.iter().fold(0.0f64, |a, &v| a.max(v.abs())) + 1e-12;
    g_q.data.iter().zip(&t).map(|(g, tv)| (*g as f64) * tv / d).sum()
}

#[test]
fn weight_quantizer_ste_matches_surrogate_fd() {
    let mut rng = Rng::new(43);
    let mut w = randn(&[3, 3, 2, 4], 0.7, &mut rng);
    // make the argmax unambiguous so the surrogate stays smooth under FD
    w.data[17] = 4.0;
    let bits = QuantBits::default();
    let ctx = grad::weight_quant_fwd(&w, &bits, 4);
    let g_q = randn(&w.shape, 1.0, &mut rng);
    let dw = grad::weight_quant_bwd(&ctx, &g_q);

    let eps = 1e-4f32;
    let mut checked_argmax = false;
    for t in 0..24 {
        let i = if t == 23 {
            checked_argmax = true;
            17 // the argmax path must be covered explicitly
        } else {
            (t * 7919) % w.len()
        };
        let mut wp = w.clone();
        wp.data[i] += eps;
        let mut wm = w.clone();
        wm.data[i] -= eps;
        let fd = (wq_surrogate_loss(&wp, &g_q) - wq_surrogate_loss(&wm, &g_q)) / (2.0 * eps as f64);
        assert_close(fd, dw.data[i] as f64, &format!("quantizer dw[{i}]"));
    }
    assert!(checked_argmax);
}

#[test]
fn fc_backward_matches_surrogate_fd() {
    // FC layer: y = x·(s·q_unit(w)) + b with s frozen (stop-grad) and the
    // round removed in the surrogate — the exact STE contract.
    let mut rng = Rng::new(44);
    let (bsz, cin, o) = (4usize, 6usize, 3usize);
    let x = randn(&[bsz, cin], 1.0, &mut rng);
    let w = randn(&[cin, o], 0.6, &mut rng);
    let bits = QuantBits::default();
    let ctx = grad::weight_quant_fwd(&w, &bits, o);
    let s0 = ctx.scale;
    let g = randn(&[bsz, o], 1.0, &mut rng);

    // analytic backward, mirroring NativeTrainer::fc_bwd
    let mut dq = gemm_tn(bsz, cin, o, &x.data, &g.data);
    for v in &mut dq {
        *v *= s0;
    }
    let dw = grad::weight_quant_bwd(&ctx, &Tensor::from_vec(&[cin, o], dq));
    let mut dx = gemm_nt(bsz, o, cin, &g.data, &ctx.q_unit.data);
    for v in &mut dx {
        *v *= s0;
    }

    let surrogate = |w: &Tensor, x: &Tensor| -> f64 {
        let t: Vec<f64> = w.data.iter().map(|&v| (v as f64).tanh()).collect();
        let d = t.iter().fold(0.0f64, |a, &v| a.max(v.abs())) + 1e-12;
        let mut l = 0.0f64;
        for i in 0..bsz {
            for j in 0..o {
                let mut acc = 0.0f64;
                for c in 0..cin {
                    acc += (x.data[i * cin + c] as f64) * t[c * o + j] / d;
                }
                l += (g.data[i * o + j] as f64) * acc * s0 as f64;
            }
        }
        l
    };
    let eps = 1e-4f32;
    for i in 0..w.len() {
        let mut wp = w.clone();
        wp.data[i] += eps;
        let mut wm = w.clone();
        wm.data[i] -= eps;
        let fd = (surrogate(&wp, &x) - surrogate(&wm, &x)) / (2.0 * eps as f64);
        assert_close(fd, dw.data[i] as f64, &format!("fc dw[{i}]"));
    }
    // dx: the quantized forward is linear in x (q_unit does not depend on
    // x), so FD against the real quantized product is exact.
    let qloss = |x: &Tensor| -> f64 {
        let y = gemm(bsz, cin, o, &x.data, &ctx.q_unit.data);
        y.iter()
            .zip(&g.data)
            .map(|(yv, gv)| (*yv as f64) * (s0 as f64) * (*gv as f64))
            .sum()
    };
    let eps = 1e-2f32;
    for i in 0..x.len() {
        let mut xp = x.clone();
        xp.data[i] += eps;
        let mut xm = x.clone();
        xm.data[i] -= eps;
        let fd = (qloss(&xp) - qloss(&xm)) / (2.0 * eps as f64);
        assert_close(fd, dx[i] as f64, &format!("fc dx[{i}]"));
    }
}

#[test]
fn activation_ste_matches_surrogate_fd() {
    // points safely away from the 0 / 1 kinks
    let x = Tensor::from_vec(&[6], vec![-0.6, 0.2, 0.45, 0.8, 1.3, 0.95]);
    let bits = QuantBits::default();
    let (_, mask) = grad::act_fwd(&x, &bits);
    let g = Tensor::from_vec(&[6], vec![1.0, -2.0, 0.5, 1.5, 3.0, -1.0]);
    let dx = grad::act_bwd(&mask, &g);
    let surrogate = |x: &Tensor| -> f64 {
        x.data
            .iter()
            .zip(&g.data)
            .map(|(&v, &gv)| (gv as f64) * (v.clamp(0.0, 1.0) as f64))
            .sum()
    };
    let eps = 1e-3f32;
    for i in 0..x.len() {
        let mut xp = x.clone();
        xp.data[i] += eps;
        let mut xm = x.clone();
        xm.data[i] -= eps;
        let fd = (surrogate(&xp) - surrogate(&xm)) / (2.0 * eps as f64);
        assert_close(fd, dx.data[i] as f64, &format!("act dx[{i}]"));
    }
}

#[test]
fn pim_gste_xi_tracks_scale_enlargement() {
    // Eqn. 8 / Appendix A3: at very low b_PIM the PIM output variance is
    // enlarged, so ξ = √(VAR[y_PIM]/VAR[y]) > 1 — the quantity the native
    // backward folds into its coefficient.
    let mut rng = Rng::new(45);
    let (m, c, k, o, uc) = (32usize, 8usize, 3usize, 16usize, 8usize);
    let cols = c * k * k;
    let a = Tensor::from_vec(&[m, cols], (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect());
    let w = Tensor::from_vec(&[cols, o], (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect());
    let chip = pim_qat::chip::ChipModel::ideal(3);
    let mut nrng = Rng::new(0);
    let y_pim = pim_qat::pim::pim_grouped_matmul(
        Scheme::BitSerial,
        QuantBits::default(),
        &a,
        &w,
        c,
        k,
        uc,
        &chip,
        &mut nrng,
    );
    // exact product in unit scale
    let au: Vec<f32> = a.data.iter().map(|&v| v / 15.0).collect();
    let wu: Vec<f32> = w.data.iter().map(|&v| v / 7.0).collect();
    let y_ex = gemm(m, cols, o, &au, &wu);
    let var = |v: &[f32]| -> f64 {
        let n = v.len() as f64;
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n;
        v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n
    };
    let xi = (var(&y_pim.data) / var(&y_ex)).sqrt();
    assert!(xi > 1.2, "xi at b_PIM=3 should enlarge the scale, got {xi}");
}

// ---------------------------------------------------------------------------
// Training smoke on the native backend
// ---------------------------------------------------------------------------

/// A down-scaled geometry so debug-mode tests stay fast.
fn micro_manifest() -> Manifest {
    let mut m = Manifest::builtin();
    let mut e = m.models.get("tiny").unwrap().clone();
    e.width = 4;
    e.image = 8;
    e.classes = 4;
    m.models.insert("micro".to_string(), e);
    m.batch = 8;
    m
}

#[test]
fn native_baseline_training_reduces_loss() {
    let m = micro_manifest();
    let job = JobConfig {
        model: "micro".to_string(),
        mode: Mode::Baseline,
        steps: 30,
        lr: 0.1,
        train_size: 96,
        test_size: 32,
        ..Default::default()
    };
    let tr = synth::generate(8, 4, job.train_size, 1);
    let te = synth::generate(8, 4, job.test_size, 2);
    let res = run_job_native(&m, &job, &tr, &te, 1).unwrap();
    assert!(res.history.iter().all(|l| l.loss.is_finite()));
    let first = res.history.first().unwrap().loss;
    let best = res.history.iter().map(|l| l.loss).fold(f32::INFINITY, f32::min);
    assert!(best < first, "loss should decrease: first {first}, best {best}");
}

#[test]
fn native_pim_qat_training_end_to_end_on_chip() {
    // The acceptance path in miniature: train mode=ours on the native
    // backend, rebuild the network from the checkpoint, evaluate on an
    // ideal 7-bit chip.
    let m = micro_manifest();
    let job = JobConfig {
        model: "micro".to_string(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps: 10,
        lr: 0.05,
        train_size: 64,
        test_size: 16,
        ..Default::default()
    };
    let tr = synth::generate(8, 4, job.train_size, 3);
    let te = synth::generate(8, 4, job.test_size, 4);
    let res = run_job_native(&m, &job, &tr, &te, 2).unwrap();
    assert!(res.history.iter().all(|l| l.loss.is_finite()));
    assert!(res.software_acc.is_finite());

    let net = network_from_ckpt(&m, &res.ckpt).unwrap();
    let chip = pim_qat::chip::ChipModel::ideal(7);
    let mut rng = Rng::new(5);
    let acc = net
        .evaluate(
            &te,
            8,
            &ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &chip },
            &mut rng,
        )
        .unwrap();
    assert!((0.0..=100.0).contains(&acc), "chip accuracy {acc}");
}
