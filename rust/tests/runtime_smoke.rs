//! Runtime integration: load real HLO artifacts through PJRT, run init /
//! train / eval, and prove the training loop learns.  Also exercises the
//! Pallas-lowered kernel artifact (interpret-mode Pallas → HLO → PJRT).
//!
//! Requires `make artifacts` and the `pjrt` cargo feature (the default
//! build has no XLA client).  All tests share one Runtime (one PJRT client
//! per process) via a lazily-initialized static.
#![cfg(feature = "pjrt")]

use std::sync::OnceLock;

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::synth;
use pim_qat::runtime::literal::{scalar_i32, tensor_to_literal, to_scalar_f32, to_vec_f32};
use pim_qat::runtime::{Kind, Runtime};
use pim_qat::tensor::Tensor;
use pim_qat::train;
use pim_qat::util::rng::Rng;

fn rt() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = pim_qat::runtime::manifest::default_artifacts_dir();
        Runtime::new(&dir).expect("run `make artifacts` before cargo test")
    })
}

#[test]
fn manifest_has_expected_artifacts() {
    let m = &rt().manifest;
    for name in [
        "tiny_init",
        "tiny_eval",
        "tiny_train_baseline",
        "tiny_train_ams",
        "tiny_train_ours_native_uc1",
        "tiny_train_ours_bit_serial_uc8",
        "tiny_train_ours_differential_uc8",
        "tiny_pimeval_bit_serial_uc8",
        "kernel_pim_mac_pallas",
    ] {
        assert!(m.artifacts.contains_key(name), "{name} missing");
    }
    assert_eq!(m.b_w, 4);
}

#[test]
fn init_produces_manifest_shapes() {
    let init = rt().load("tiny_init").unwrap();
    assert_eq!(init.spec.kind, Kind::Init);
    let outs = init.run(&[scalar_i32(7)]).unwrap();
    let entry = rt().manifest.model("tiny").unwrap();
    assert_eq!(outs.len(), 2 * entry.param_paths.len() + entry.state_paths.len());
    // a randomly-initialized tensor (sorted order starts with bn0/beta,
    // which is zeros — use the first conv weight instead)
    let ci = entry
        .param_paths
        .iter()
        .position(|p| p == "conv0/w")
        .expect("conv0/w in manifest");
    let v = to_vec_f32(&outs[ci]).unwrap();
    let want: usize = entry.param_shapes[ci].iter().product();
    assert_eq!(v.len(), want);
    // different seeds give different params
    let outs2 = init.run(&[scalar_i32(8)]).unwrap();
    assert_ne!(to_vec_f32(&outs2[ci]).unwrap(), v);
    // same seed reproduces
    let outs3 = init.run(&[scalar_i32(7)]).unwrap();
    assert_eq!(to_vec_f32(&outs3[ci]).unwrap(), v);
}

#[test]
fn pallas_kernel_artifact_runs_and_matches_jnp_twin() {
    let pallas = rt().load("kernel_pim_mac_pallas").unwrap();
    let jnp = rt().load("kernel_pim_mac_jnp").unwrap();
    let (m, g, n, o) = (256usize, 2usize, 72usize, 16usize);
    let mut rng = Rng::new(11);
    let a = Tensor::from_vec(
        &[m, g, n],
        (0..m * g * n).map(|_| rng.int_in(0, 15) as f32 / 15.0).collect(),
    );
    let w = Tensor::from_vec(
        &[g, n, o],
        (0..g * n * o).map(|_| rng.int_in(-7, 7) as f32 / 7.0).collect(),
    );
    let lv = Tensor::from_vec(&[1], vec![127.0]);
    let inputs = [
        tensor_to_literal(&a).unwrap(),
        tensor_to_literal(&w).unwrap(),
        tensor_to_literal(&lv).unwrap(),
    ];
    let y_p = to_vec_f32(&pallas.run(&inputs).unwrap()[0]).unwrap();
    let y_j = to_vec_f32(&jnp.run(&inputs).unwrap()[0]).unwrap();
    assert_eq!(y_p.len(), m * o);
    let max_diff = y_p
        .iter()
        .zip(&y_j)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-5, "pallas vs jnp lowering diff {max_diff}");

    // ... and the rust PIM engine agrees with both (three-way pin)
    let chip = pim_qat::chip::ChipModel::ideal(7);
    let a_int = a.clone().map(|v| (v * 15.0).round());
    let w_int_cols = {
        // [G,N,O] -> [G*N, O] with ints
        let mut d = vec![0.0f32; g * n * o];
        for gi in 0..g {
            for ni in 0..n {
                for oi in 0..o {
                    d[(gi * n + ni) * o + oi] =
                        (w.data[(gi * n + ni) * o + oi] * 7.0).round();
                }
            }
        }
        Tensor::from_vec(&[g * n, o], d)
    };
    let mut nrng = Rng::new(0);
    let y_r = pim_qat::pim::pim_grouped_matmul(
        Scheme::BitSerial,
        pim_qat::pim::QuantBits::default(),
        &a_int.reshape(&[m, g * n]),
        &w_int_cols,
        g * n,
        1,
        n,
        &chip,
        &mut nrng,
    );
    let max_diff_r = y_r
        .data
        .iter()
        .zip(&y_p)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff_r < 2e-5, "rust engine vs pallas diff {max_diff_r}");
}

#[test]
fn training_learns_and_deploys_to_chip() {
    // Small but real end-to-end: train PIM-QAT bit-serial on synth data,
    // verify the loss drops and the checkpoint evaluates sanely both on the
    // digital path and on the chip simulator.
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps: 60,
        lr: 0.1,
        train_size: 256,
        test_size: 128,
        ..Default::default()
    };
    let train_ds = synth::generate(16, 10, job.train_size, 1);
    let test_ds = synth::generate(16, 10, job.test_size, 2);
    let res = train::run_job(rt(), &job, &train_ds, &test_ds, 5).unwrap();

    let first = res.history.first().unwrap().loss;
    let last = res.history.last().unwrap().loss;
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first, "loss should decrease: {first} -> {last}");
    assert!(res.software_acc > 15.0, "software acc {YELLOW}", YELLOW = res.software_acc);

    // chip-sim evaluation at the training resolution should be in the same
    // ballpark as software for 7-bit ideal chips
    let net = train::network_from_ckpt(&rt().manifest, &res.ckpt).unwrap();
    let chip = pim_qat::chip::ChipModel::ideal(7);
    let mut rng = Rng::new(3);
    let acc = net
        .evaluate(
            &test_ds,
            32,
            &pim_qat::nn::ExecSpec::Pim {
                scheme: Scheme::BitSerial,
                unit_channels: 8,
                chip: &chip,
            },
            &mut rng,
        )
        .unwrap();
    assert!(
        (acc - res.software_acc).abs() < 25.0,
        "ideal-7bit chip acc {acc} vs software {}",
        res.software_acc
    );
}

#[test]
fn baseline_trains_too() {
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Baseline,
        steps: 30,
        train_size: 128,
        test_size: 64,
        ..Default::default()
    };
    let train_ds = synth::generate(16, 10, job.train_size, 3);
    let test_ds = synth::generate(16, 10, job.test_size, 4);
    let res = train::run_job(rt(), &job, &train_ds, &test_ds, 5).unwrap();
    assert!(res.history.last().unwrap().loss.is_finite());
}

#[test]
fn pimeval_artifact_matches_chip_sim() {
    // The lowered PIM-eval forward (jax) and the rust chip simulator must
    // agree on accuracy counts for the same checkpoint — the strongest
    // system-level cross-check (full model, both implementations).
    let job = JobConfig {
        model: "tiny".into(),
        steps: 20,
        train_size: 128,
        test_size: 64,
        ..Default::default()
    };
    let train_ds = synth::generate(16, 10, job.train_size, 5);
    let test_ds = synth::generate(16, 10, job.test_size, 6);
    let res = train::run_job(rt(), &job, &train_ds, &test_ds, 10).unwrap();

    let ev = rt().load("tiny_pimeval_bit_serial_uc8").unwrap();
    let bs = ev.spec.batch;
    let idx: Vec<usize> = (0..bs).collect();
    let mut drng = Rng::new(0);
    let batch = test_ds.batch(&idx, false, &mut drng);
    let mut inputs = Vec::new();
    for (_, t) in res.ckpt.params.iter().chain(res.ckpt.state.iter()) {
        inputs.push(tensor_to_literal(t).unwrap());
    }
    inputs.push(tensor_to_literal(&batch.x).unwrap());
    inputs.push(pim_qat::runtime::literal::vec_i32(&batch.y));
    inputs.push(pim_qat::runtime::literal::scalar_f32(127.0));
    inputs.push(pim_qat::runtime::literal::scalar_f32(1.0));
    let outs = ev.run(&inputs).unwrap();
    let jax_correct = to_scalar_f32(&outs[1]).unwrap();

    let net = train::network_from_ckpt(&rt().manifest, &res.ckpt).unwrap();
    let chip = pim_qat::chip::ChipModel::ideal(7);
    let mut rng = Rng::new(0);
    let logits = net
        .forward(
            &batch.x,
            &pim_qat::nn::ExecSpec::Pim {
                scheme: Scheme::BitSerial,
                unit_channels: 8,
                chip: &chip,
            },
            &mut rng,
        )
        .unwrap();
    let preds = pim_qat::tensor::ops::argmax_rows(&logits);
    let rust_correct = preds
        .iter()
        .zip(&batch.y)
        .filter(|(p, &t)| **p == t as usize)
        .count() as f32;
    assert!(
        (jax_correct - rust_correct).abs() <= 2.0,
        "jax pimeval {jax_correct} vs rust chip sim {rust_correct}"
    );
}
