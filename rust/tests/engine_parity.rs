//! Engine parity suite (no external goldens needed):
//!
//! 1. The integer-native plane-extraction path must match the seed float
//!    path (float `div/floor/mod` slicing + f32 plane GEMM + scalar
//!    conversion) **bit-for-bit** on every scheme, since integer plane sums
//!    are exactly representable in f32.
//! 2. The multi-threaded engine must be bit-identical at 1, 2, and N
//!    threads for every scheme with thermal noise enabled — the counter-
//!    based noise RNG is addressed by position, not by draw order.

use pim_qat::chip::{ChipModel, Converter, FaultModel, FaultProfile};
use pim_qat::config::Scheme;
use pim_qat::pim::layout::{pack_bin_plane, plan_groups};
use pim_qat::pim::{plane_full_scale, PimEngine, QuantBits};
use pim_qat::tensor::kernels::{self, autotune, blocked, scalar};
use pim_qat::tensor::Tensor;
use pim_qat::util::rng::Rng;

/// The seed implementation's execution path, kept as the float oracle:
/// DAC planes via `(a / Δ^l).floor() % Δ`, f32 plane GEMM, scalar
/// conversion.  Noiseless chips only (the seed consumed a sequential RNG;
/// the rewrite uses a positional one, so noisy streams differ by design).
#[allow(clippy::too_many_arguments)]
fn float_reference_matmul(
    scheme: Scheme,
    bits: QuantBits,
    a: &Tensor,
    w: &Tensor,
    c_in: usize,
    kernel: usize,
    unit_channels: usize,
    chip: &ChipModel,
) -> Tensor {
    assert_eq!(chip.noise_lsb, 0.0, "float oracle is noiseless");
    let m = a.shape[0];
    let cols = a.shape[1];
    let out = w.shape[1];
    let plan = plan_groups(c_in, kernel, unit_channels);
    let n = plan.n;
    assert_eq!(cols, plan.groups * n);
    let fs = plane_full_scale(scheme, &bits, n);
    let conv = Converter::new(chip, fs, out);
    let mut rng = Rng::new(0); // unused: noiseless
    let n_slices = bits.n_slices();
    let delta = bits.delta();
    let signed = matches!(scheme, Scheme::Native);

    let mut y = vec![0.0f32; m * out];
    let mut a_plane = vec![0.0f32; m * n];
    let mut s = vec![0.0f32; m * out];
    let gemm = |a_plane: &[f32], wg: &[f32], s: &mut [f32]| {
        s.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..m {
            for kk in 0..n {
                let aik = a_plane[i * n + kk];
                for o in 0..out {
                    s[i * out + o] += aik * wg[kk * out + o];
                }
            }
        }
    };

    for g in 0..plan.groups {
        // group weights, float-decomposed as in the seed
        let wg: Vec<f32> = (g * n..(g + 1) * n)
            .flat_map(|r| w.data[r * out..(r + 1) * out].to_vec())
            .collect();
        for l in 0..n_slices {
            let slice_w = (delta as f32).powi(l as i32);
            if n_slices == 1 {
                for i in 0..m {
                    a_plane[i * n..(i + 1) * n]
                        .copy_from_slice(&a.data[i * cols + g * n..i * cols + (g + 1) * n]);
                }
            } else {
                let shift = (delta as f32).powi(l as i32);
                for i in 0..m {
                    for j in 0..n {
                        let src = a.data[i * cols + g * n + j];
                        a_plane[i * n + j] = ((src / shift).floor()) % delta as f32;
                    }
                }
            }
            match scheme {
                Scheme::Native => {
                    gemm(&a_plane, &wg, &mut s);
                    for i in 0..m {
                        for o in 0..out {
                            y[i * out + o] +=
                                slice_w * conv.convert(s[i * out + o], o, signed, &mut rng);
                        }
                    }
                }
                Scheme::Differential => {
                    let wp: Vec<f32> = wg.iter().map(|&v| v.max(0.0)).collect();
                    let wn: Vec<f32> = wg.iter().map(|&v| (-v).max(0.0)).collect();
                    gemm(&a_plane, &wp, &mut s);
                    for i in 0..m {
                        for o in 0..out {
                            y[i * out + o] +=
                                slice_w * conv.convert(s[i * out + o], o, false, &mut rng);
                        }
                    }
                    gemm(&a_plane, &wn, &mut s);
                    for i in 0..m {
                        for o in 0..out {
                            y[i * out + o] -=
                                slice_w * conv.convert(s[i * out + o], o, false, &mut rng);
                        }
                    }
                }
                Scheme::BitSerial => {
                    for k in 0..bits.b_w {
                        let plane: Vec<f32> = wg
                            .iter()
                            .map(|&v| {
                                let vi = v as i32;
                                let u = if vi < 0 { vi + (1 << bits.b_w) } else { vi } as u32;
                                ((u >> k) & 1) as f32
                            })
                            .collect();
                        let sign = if k == bits.b_w - 1 { -1.0 } else { 1.0 };
                        let bit_w = sign * (1u32 << k) as f32 * slice_w;
                        gemm(&a_plane, &plane, &mut s);
                        for i in 0..m {
                            for o in 0..out {
                                y[i * out + o] +=
                                    bit_w * conv.convert(s[i * out + o], o, false, &mut rng);
                            }
                        }
                    }
                }
            }
        }
    }
    let denom = (bits.w_levels() * bits.a_levels()) as f32;
    for v in &mut y {
        *v /= denom;
    }
    Tensor::from_vec(&[m, out], y)
}

fn random_case(bits: &QuantBits, seed: u64) -> (Tensor, Tensor, usize, usize, usize) {
    let mut rng = Rng::new(seed);
    let (m, c, k, o, uc) = (7usize, 4usize, 3usize, 5usize, 2usize);
    let cols = c * k * k;
    let al = bits.a_levels() as i64;
    let wl = bits.w_levels() as i64;
    let a = Tensor::from_vec(
        &[m, cols],
        (0..m * cols).map(|_| rng.int_in(0, al) as f32).collect(),
    );
    let w = Tensor::from_vec(
        &[cols, o],
        (0..cols * o).map(|_| rng.int_in(-wl, wl) as f32).collect(),
    );
    (a, w, c, k, uc)
}

#[test]
fn integer_path_matches_seed_float_path_bitwise() {
    for bits in [QuantBits::default(), QuantBits { b_w: 4, b_a: 4, m: 1 }] {
        let (a, w, c, k, uc) = random_case(&bits, 31 + bits.m as u64);
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            for chip in [
                ChipModel::ideal(5),
                ChipModel::ideal(7),
                ChipModel::real(3).with_noise(0.0),
            ] {
                let want = float_reference_matmul(scheme, bits, &a, &w, c, k, uc, &chip);
                let engine = PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(1);
                let mut rng = Rng::new(0);
                let got = engine.matmul(&a, &chip, &mut rng);
                assert_eq!(
                    got.data, want.data,
                    "{scheme} m={} b_pim={} integer path diverged from float path",
                    bits.m, chip.b_pim
                );
            }
        }
    }
}

#[test]
fn threaded_engine_bit_identical_all_schemes_with_noise() {
    for bits in [QuantBits::default(), QuantBits { b_w: 4, b_a: 4, m: 1 }] {
        let (a, w, c, k, uc) = random_case(&bits, 77 + bits.m as u64);
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            for chip in [
                ChipModel::ideal(7).with_noise(0.5),
                ChipModel::real(9), // measured curves + 0.35 LSB noise
            ] {
                let run = |threads: usize| {
                    let engine =
                        PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(threads);
                    let mut rng = Rng::new(11);
                    engine.matmul(&a, &chip, &mut rng)
                };
                let y1 = run(1);
                for threads in [2usize, 3, 8] {
                    let yt = run(threads);
                    assert_eq!(
                        y1.data, yt.data,
                        "{scheme} m={} noise={} not bit-identical at {threads} threads",
                        bits.m, chip.noise_lsb
                    );
                }
                // sanity: the noise field actually perturbed something
                let noiseless = {
                    let engine =
                        PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(1);
                    let mut rng = Rng::new(11);
                    engine.matmul(&a, &ChipModel::ideal(chip.b_pim), &mut rng)
                };
                assert_ne!(y1.data, noiseless.data, "{scheme}: noise had no effect");
            }
        }
    }
}

#[test]
fn reprogram_matches_fresh_prepare_bitwise_with_noise() {
    // The engine-cache contract (§Perf L3.5): an engine kept alive across
    // training steps and incrementally reprogrammed must be
    // indistinguishable — bit for bit, noise on — from one freshly
    // prepared with the same weights, for every scheme, including when
    // most groups take the unchanged-skip path.
    let bits = QuantBits::default();
    let (m, c, k, o, uc) = (6usize, 4usize, 3usize, 5usize, 1usize); // 4 groups
    let cols = c * k * k;
    let mut rng = Rng::new(123);
    let a = Tensor::from_vec(
        &[m, cols],
        (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
    );
    let w0 = Tensor::from_vec(
        &[cols, o],
        (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
    );
    let chip = ChipModel::ideal(7).with_noise(0.5);
    let groups = plan_groups(c, k, uc).groups;
    for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
        let mut cached = PimEngine::prepare(scheme, bits, &w0, c, k, uc).with_threads(2);
        // identical weights: every group takes the skip path
        assert_eq!(cached.reprogram(&w0.data), 0, "{scheme}: all groups unchanged");
        // drift a single weight per step, as late low-b_w training does
        let mut w = w0.clone();
        for step in 0..3usize {
            let i = (step * 131) % (cols * o);
            w.data[i] = if w.data[i] >= 7.0 { -7.0 } else { w.data[i] + 1.0 };
            let rewritten = cached.reprogram(&w.data);
            assert!(
                rewritten >= 1 && rewritten < groups,
                "{scheme} step {step}: expected a partial rewrite, got {rewritten}/{groups}"
            );
            let fresh = PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(2);
            let mut r1 = Rng::new(77);
            let mut r2 = Rng::new(77);
            let y_cached = cached.matmul(&a, &chip, &mut r1);
            let y_fresh = fresh.matmul(&a, &chip, &mut r2);
            assert_eq!(
                y_cached.data, y_fresh.data,
                "{scheme} step {step}: reprogrammed engine diverged from fresh prepare"
            );
        }
    }
}

/// The fault-subsystem determinism contract: column faults are drawn from
/// the positional counter RNG keyed by `(seed, chip_id, step)`, never from
/// a sequential stream — so an injured engine must be bit-identical at any
/// thread count, with thermal noise, drift, and bursts all enabled.
#[test]
fn faulty_engine_bit_identical_across_thread_counts() {
    let bits = QuantBits::default();
    let (a, w, c, k, uc) = random_case(&bits, 0xFA);
    // drift + d2d + stuck + bursts, evaluated mid-drift (step 40)
    let fm = FaultModel::new(FaultProfile::severe().on_chip(0xBAD)).at_step(40);
    for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
        let chip = ChipModel::ideal(7).with_noise(0.5);
        let run = |threads: usize| {
            let mut engine = PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(threads);
            engine.set_faults(Some(fm));
            let mut rng = Rng::new(21);
            engine.matmul(&a, &chip, &mut rng)
        };
        let y1 = run(1);
        for threads in [2usize, 8] {
            assert_eq!(
                y1.data,
                run(threads).data,
                "{scheme}: injured engine not bit-identical at {threads} threads"
            );
        }
        // the injury must actually show up against the healthy engine
        let healthy = {
            let engine = PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(1);
            let mut rng = Rng::new(21);
            engine.matmul(&a, &chip, &mut rng)
        };
        assert_ne!(y1.data, healthy.data, "{scheme}: fault model had no effect");
    }
}

/// JSON round-trip is part of the reproducibility story: a profile shipped
/// to another machine (or another thread count) must rebuild the same
/// injured chip bit for bit.
#[test]
fn fault_profile_json_roundtrip_reproduces_engine_bitwise() {
    let bits = QuantBits::default();
    let (a, w, c, k, uc) = random_case(&bits, 0xFB);
    let profile = FaultProfile::moderate().on_chip(0x51);
    let dir = std::env::temp_dir().join("pimqat_fault_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profile.json");
    profile.save(&path).unwrap();
    let back = FaultProfile::parse(path.to_str().unwrap()).unwrap();
    assert_eq!(profile, back);
    let chip = ChipModel::ideal(7).with_noise(0.35);
    let run = |p: FaultProfile, threads: usize| {
        let mut engine = PimEngine::prepare(Scheme::BitSerial, bits, &w, c, k, uc)
            .with_threads(threads);
        engine.set_faults(Some(FaultModel::new(p).at_step(7)));
        let mut rng = Rng::new(5);
        engine.matmul(&a, &chip, &mut rng)
    };
    assert_eq!(
        run(profile, 1).data,
        run(back, 8).data,
        "round-tripped profile must rebuild the identical injured chip at any thread count"
    );
}

/// Shape sweep for the kernel-parity property tests: primes, powers of
/// two, and every tail class around the 4/8/16-lane SIMD widths (NEON /
/// AVX2 / AVX-512) and the 64-bit packed-word width.
const ODD_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 1, 7),
    (2, 3, 8),
    (3, 5, 9),
    (1, 4, 15),
    (4, 7, 16),
    (2, 9, 17),
    (5, 2, 31),
    (3, 13, 33),
    (2, 17, 63),
    (3, 64, 64),
    (1, 130, 65),
    (2, 31, 100),
    (6, 144, 32),
    (4, 72, 12),
    (2, 9, 129),
    (1, 3, 47),
    (3, 6, 48),
];

/// The L3.6 exactness contract: every integer kernel arm is bit-identical
/// to the scalar reference on every shape — k/n tails that are not
/// multiples of the SIMD width included.  The dispatched arm here is
/// whatever `select()` picked (avx512 ≻ avx2 on x86_64, neon on aarch64);
/// on hosts without SIMD it *is* scalar and this passes trivially.  The
/// CI runners exercise the real comparison, and the `PIM_QAT_NO_SIMD=1`
/// test leg pins the forced-scalar path.
#[test]
fn integer_kernel_arms_bit_identical_to_scalar_on_odd_shapes() {
    let active = kernels::active();
    let mut rng = Rng::new(0x51D);
    for &(m, k, n) in ODD_SHAPES {
        let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
        // nonzero initial C pins the accumulate (+=) semantics too
        let c0: Vec<i32> = (0..m * n).map(|_| rng.int_in(-100, 100) as i32).collect();

        let w16: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
        let mut cs = c0.clone();
        let mut cd = c0.clone();
        (scalar::TABLE.gemm_acc_u8_i16)(m, k, n, &a, &w16, &mut cs);
        (active.gemm_acc_u8_i16)(m, k, n, &a, &w16, &mut cd);
        assert_eq!(cs, cd, "u8i16 ({m},{k},{n}) diverged on arm {}", active.name);

        let wbin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
        let mut cs = c0.clone();
        let mut cd = c0.clone();
        (scalar::TABLE.gemm_acc_u8_bin)(m, k, n, &a, &wbin, &mut cs);
        (active.gemm_acc_u8_bin)(m, k, n, &a, &wbin, &mut cd);
        assert_eq!(cs, cd, "u8bin ({m},{k},{n}) diverged on arm {}", active.name);

        // the packed layout of the same plane: scalar-packed must match
        // scalar-unpacked (layout parity), and the dispatched arm must
        // match scalar-packed (SIMD parity)
        let wp = pack_bin_plane(&wbin, k, n);
        let mut cp = c0.clone();
        let mut cpd = c0.clone();
        (scalar::TABLE.gemm_acc_u8_bin_packed)(m, k, n, &a, &wp, &mut cp);
        (active.gemm_acc_u8_bin_packed)(m, k, n, &a, &wp, &mut cpd);
        assert_eq!(cs, cp, "packed layout ({m},{k},{n}) diverged from u8 plane");
        assert_eq!(cp, cpd, "binpacked ({m},{k},{n}) diverged on arm {}", active.name);
    }
}

/// f32 arms: deterministic fixed tile order per arm, scalar-equivalent to
/// the documented tolerance (1e-3 absolute on unit-scale operands —
/// DESIGN.md §Kernel dispatch).
#[test]
fn f32_kernel_arms_match_scalar_within_tolerance() {
    let active = kernels::active();
    let mut rng = Rng::new(0xF32);
    for &(m, k, n) in ODD_SHAPES {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let mut cs = vec![0.0f32; m * n];
        let mut cd = vec![0.0f32; m * n];
        (scalar::TABLE.gemm_acc)(m, k, n, &a, &b, &mut cs);
        (active.gemm_acc)(m, k, n, &a, &b, &mut cd);
        for (x, y) in cs.iter().zip(&cd) {
            assert!((x - y).abs() < 1e-3, "gemm_acc ({m},{k},{n}): {x} vs {y}");
        }
        // determinism: a second dispatched run is bit-identical
        let mut cd2 = vec![0.0f32; m * n];
        (active.gemm_acc)(m, k, n, &a, &b, &mut cd2);
        assert_eq!(cd, cd2, "gemm_acc ({m},{k},{n}) must be deterministic");

        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let mut cs = vec![0.0f32; m * n];
        let mut cd = vec![0.0f32; m * n];
        (scalar::TABLE.gemm_nt_acc)(m, k, n, &a, &bt, &mut cs);
        (active.gemm_nt_acc)(m, k, n, &a, &bt, &mut cd);
        for (x, y) in cs.iter().zip(&cd) {
            assert!((x - y).abs() < 1e-3, "gemm_nt ({m},{k},{n}): {x} vs {y}");
        }

        let a2: Vec<f32> = (0..k * m)
            .map(|_| if rng.below(3) == 0 { 0.0 } else { rng.normal_in(0.0, 1.0) })
            .collect();
        let b2: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let mut cs = vec![0.0f32; m * n];
        let mut cd = vec![0.0f32; m * n];
        (scalar::TABLE.gemm_tn_acc)(k, m, n, &a2, &b2, &mut cs);
        (active.gemm_tn_acc)(k, m, n, &a2, &b2, &mut cd);
        for (x, y) in cs.iter().zip(&cd) {
            assert!((x - y).abs() < 1e-3, "gemm_tn ({k},{m},{n}): {x} vs {y}");
        }
    }
}

/// L3.9: the packed-panel blocked driver, driven by the dispatched arm's
/// tile microkernel, must hold the f32 contract under **every** autotune
/// tile candidate — within 1e-3 of scalar on unit-scale data, and bitwise
/// rerun-stable once the tile is pinned (the `PIM_QAT_TILE` guarantee;
/// `gemm_acc_packed_with` is exactly the pinned-tile path).
#[test]
fn blocked_f32_holds_contract_for_every_autotune_candidate() {
    let active = kernels::active();
    let mut rng = Rng::new(0x7115);
    for &t in autotune::CANDIDATES {
        for &(m, k, n) in ODD_SHAPES {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut cs = vec![0.0f32; m * n];
            (scalar::TABLE.gemm_acc)(m, k, n, &a, &b, &mut cs);
            let mut cb = vec![0.0f32; m * n];
            blocked::gemm_acc_packed_with(t, m, k, n, &a, &b, &mut cb, active.gemm_acc_tile);
            for (x, y) in cs.iter().zip(&cb) {
                assert!((x - y).abs() < 1e-3, "tile {t:?} ({m},{k},{n}): {x} vs {y}");
            }
            let mut cb2 = vec![0.0f32; m * n];
            blocked::gemm_acc_packed_with(t, m, k, n, &a, &b, &mut cb2, active.gemm_acc_tile);
            assert_eq!(cb, cb2, "tile {t:?} ({m},{k},{n}) must be bitwise rerun-stable");
        }
    }
}

/// Integer-valued f32 data keeps every product and partial sum exactly
/// representable, so the blocked walk must agree with scalar **bitwise**
/// for every arm and every tile candidate — this pins the block/pack
/// bookkeeping itself (offsets, tails, panel reuse), with no tolerance to
/// hide an indexing bug behind.
#[test]
fn blocked_f32_bitwise_exact_on_integer_data_for_every_candidate() {
    let active = kernels::active();
    let mut rng = Rng::new(0x1B17);
    for &t in autotune::CANDIDATES {
        for &(m, k, n) in ODD_SHAPES {
            let a: Vec<f32> = (0..m * k).map(|_| rng.int_in(-7, 7) as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.int_in(-7, 7) as f32).collect();
            let mut cs = vec![0.0f32; m * n];
            (scalar::TABLE.gemm_acc)(m, k, n, &a, &b, &mut cs);
            let mut cb = vec![0.0f32; m * n];
            blocked::gemm_acc_packed_with(t, m, k, n, &a, &b, &mut cb, active.gemm_acc_tile);
            assert_eq!(cs, cb, "tile {t:?} ({m},{k},{n}) arm {}", active.name);
        }
    }
}

/// Packed-u64 plane programming parity: a bit-serial engine that has been
/// incrementally reprogrammed (skip path included) must still match the
/// seed float oracle — which decomposes weights one plane element per
/// slot, the u8-plane layout — bit for bit.
#[test]
fn packed_plane_programming_matches_u8_layout_through_reprogram() {
    let bits = QuantBits::default();
    // o=70: the last packed word is partial, so pad-bit handling is on the path
    let (m, c, k, o, uc) = (5usize, 4usize, 3usize, 70usize, 2usize);
    let cols = c * k * k;
    let mut rng = Rng::new(0xACE);
    let a = Tensor::from_vec(
        &[m, cols],
        (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
    );
    let w0 = Tensor::from_vec(
        &[cols, o],
        (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
    );
    let mut engine = PimEngine::prepare(Scheme::BitSerial, bits, &w0, c, k, uc).with_threads(2);
    let mut w = w0.clone();
    for step in 0..3usize {
        // drift one weight: one group rewrites, the other takes the skip path
        let i = (step * 97) % (cols * o);
        w.data[i] = if w.data[i] >= 7.0 { -7.0 } else { w.data[i] + 1.0 };
        engine.reprogram(&w.data);
        for chip in [ChipModel::ideal(5), ChipModel::real(3).with_noise(0.0)] {
            let want = float_reference_matmul(Scheme::BitSerial, bits, &a, &w, c, k, uc, &chip);
            let mut r = Rng::new(0);
            let got = engine.matmul(&a, &chip, &mut r);
            assert_eq!(
                got.data, want.data,
                "step {step} b_pim={}: packed planes diverged from the u8-layout oracle",
                chip.b_pim
            );
        }
    }
}

#[test]
fn dac_plane_shift_mask_matches_float_slicing() {
    // the satellite parity check at the formula level: (a >> m·l) & (Δ-1)
    // must equal floor(a / Δ^l) mod Δ on the whole activation grid.
    for m in [1u32, 2, 4] {
        let bits = QuantBits { b_w: 4, b_a: 4, m };
        let delta = bits.delta();
        for l in 0..bits.n_slices() {
            let shift_f = (delta as f32).powi(l as i32);
            for v in 0..=bits.a_levels() as u32 {
                let float_way = ((v as f32 / shift_f).floor()) % delta as f32;
                let int_way = ((v >> (m * l)) & (delta - 1) as u32) as f32;
                assert_eq!(float_way, int_way, "m={m} l={l} v={v}");
            }
        }
    }
}
