//! Golden cross-validation: the rust substrates must reproduce the python
//! oracle bit-for-bit (PIM MAC, DoReFa quantizers) and the full model
//! forward to float tolerance.
//!
//! Two golden sources feed the same assertions:
//!
//!   * `tests/golden/` — a micro-geometry fixture (width=4, image=8, fixed
//!     seed) committed with the repo, emitted once by
//!     `python -m compile.goldens --micro --out-dir ../rust/tests/golden`.
//!     Always present, so the cross-check asserts on every default build.
//!   * `artifacts/golden/` — the full tiny-geometry set emitted by
//!     `make artifacts`; checked additionally whenever it exists.

use std::collections::BTreeMap;
use std::path::PathBuf;

use pim_qat::chip::ChipModel;
use pim_qat::config::Scheme;
use pim_qat::nn::{self, ExecSpec, Network};
use pim_qat::pim::{pim_grouped_matmul, QuantBits};
use pim_qat::runtime::ModelEntry;
use pim_qat::tensor::Tensor;
use pim_qat::util::json::{parse_file, Json};
use pim_qat::util::rng::Rng;

/// A golden directory plus the model-forward file it carries.
struct Source {
    dir: PathBuf,
    model_file: &'static str,
}

/// The committed micro fixture always participates; the `make artifacts`
/// output joins when present.  Missing the committed fixture is a test
/// FAILURE, not a skip — that was the skip-forever hole this closes.
fn golden_sources() -> Vec<Source> {
    let fixture = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    assert!(
        fixture.join("model_micro.json").exists(),
        "committed golden fixture missing at {} — regenerate with \
         `python3 -m compile.goldens --micro --out-dir ../rust/tests/golden`",
        fixture.display()
    );
    let mut sources = vec![Source { dir: fixture, model_file: "model_micro.json" }];
    let artifacts = pim_qat::runtime::manifest::default_artifacts_dir().join("golden");
    if artifacts.exists() {
        sources.push(Source { dir: artifacts, model_file: "model_tiny.json" });
    } else {
        eprintln!(
            "golden cross-test: {} absent (run `make artifacts`); \
             asserting on the committed micro fixture only",
            artifacts.display()
        );
    }
    sources
}

fn tensor_from(j: &Json, shape: &[usize]) -> Tensor {
    Tensor::from_vec(shape, j.as_f32_vec().expect("numeric array"))
}

#[test]
fn pim_mac_matches_python_oracle_exactly() {
    for src in golden_sources() {
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            let path = src.dir.join(format!("pim_mac_{}.json", scheme.as_str()));
            let j = parse_file(&path).expect("golden parse");
            let bits = QuantBits {
                b_w: j.get("b_w").as_i64().unwrap() as u32,
                b_a: j.get("b_a").as_i64().unwrap() as u32,
                m: j.get("m_dac").as_i64().unwrap() as u32,
            };
            for case in j.get("cases").as_arr().unwrap() {
                let (m, g, n, o) = (
                    case.get("m").as_usize().unwrap(),
                    case.get("g").as_usize().unwrap(),
                    case.get("n").as_usize().unwrap(),
                    case.get("o").as_usize().unwrap(),
                );
                let b_pim = ((case.get("levels").as_f64().unwrap() + 1.0).log2()) as u32;
                let a = tensor_from(case.get("a_int"), &[m, g * n]);
                // python weights are [G, N, O] row-major == rust [G*N, O]
                let w = tensor_from(case.get("w_int"), &[g * n, o]);
                let want = tensor_from(case.get("y"), &[m, o]);
                // geometry: treat each group as one "channel" of n columns
                // with kernel 1 so plan_groups yields exactly g groups of n
                let chip = ChipModel::ideal(b_pim);
                let mut rng = Rng::new(0);
                let got = pim_grouped_matmul(
                    scheme, bits, &a, &w, g * n, 1, n, &chip, &mut rng,
                );
                let diff = got.max_abs_diff(&want);
                assert!(
                    diff < 2e-5,
                    "{scheme} levels={} diff={diff} ({})",
                    case.get("levels").as_f64().unwrap(),
                    src.dir.display()
                );
            }
        }
    }
}

#[test]
fn dorefa_quant_matches_python() {
    for src in golden_sources() {
        let j = parse_file(&src.dir.join("quant.json")).unwrap();
        let bits = QuantBits::default();
        let shape = j.get("w_shape").as_usize_vec().unwrap();
        let w = tensor_from(j.get("w"), &shape);
        let want_q = tensor_from(j.get("q_unit"), &shape);
        let got_q = nn::quant::weight_quant_unit(&w, &bits);
        assert!(got_q.max_abs_diff(&want_q) < 1e-6, "weight quant mismatch");

        let want_s = j.get("scale").as_f64().unwrap() as f32;
        let got_s = nn::quant::weight_scale(&got_q, shape[3]);
        assert!((got_s - want_s).abs() / want_s < 1e-4, "{got_s} vs {want_s}");

        let x = tensor_from(j.get("x"), &[64]);
        let want_a = tensor_from(j.get("q_act"), &[64]);
        let got_a = nn::quant::act_quant(x, &bits);
        assert!(got_a.max_abs_diff(&want_a) < 1e-6, "act quant mismatch");
    }
}

/// Returns the network, the golden input batch, and the class count (the
/// logits column dimension — 10 for both the micro and tiny geometries).
fn load_golden_network(j: &Json) -> (Network, Tensor, usize) {
    let m = j.get("model");
    let entry = ModelEntry {
        arch: "resnet".into(),
        depth_n: m.get("depth_n").as_usize().unwrap(),
        width: m.get("width").as_usize().unwrap(),
        image: m.get("image").as_usize().unwrap(),
        classes: m.get("classes").as_usize().unwrap(),
        in_channels: 3,
        param_paths: vec![],
        param_shapes: vec![],
        state_paths: vec![],
        state_shapes: vec![],
    };
    let shapes = j.get("param_shapes").as_obj().unwrap();
    let mut params = BTreeMap::new();
    for (k, v) in j.get("params").as_obj().unwrap() {
        let shape = shapes.get(k).unwrap().as_usize_vec().unwrap();
        params.insert(k.clone(), tensor_from(v, &shape));
    }
    let mut state = BTreeMap::new();
    for (k, v) in j.get("state").as_obj().unwrap() {
        let n = v.as_arr().unwrap().len();
        state.insert(k.clone(), tensor_from(v, &[n]));
    }
    let img = entry.image;
    let classes = entry.classes;
    let x = tensor_from(j.get("x"), &[4, img, img, 3]);
    let net = Network::new(entry, QuantBits::default(), params, state).unwrap();
    (net, x, classes)
}

#[test]
fn full_model_software_logits_match_jax() {
    for src in golden_sources() {
        let j = parse_file(&src.dir.join(src.model_file)).unwrap();
        let (net, x, classes) = load_golden_network(&j);
        let mut rng = Rng::new(0);
        let got = net.forward(&x, &ExecSpec::Software, &mut rng).unwrap();
        let want = tensor_from(j.get("logits").get("software"), &[4, classes]);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 2e-3, "{}: software logits diff {diff}", src.model_file);
    }
}

#[test]
fn full_model_pim_logits_match_jax_all_schemes() {
    for src in golden_sources() {
        let j = parse_file(&src.dir.join(src.model_file)).unwrap();
        let (net, x, classes) = load_golden_network(&j);
        for (scheme, uc) in [
            (Scheme::Native, 1usize),
            (Scheme::BitSerial, 8),
            (Scheme::Differential, 8),
        ] {
            for b_pim in [5u32, 7] {
                let key = format!("{}_uc{uc}_b{b_pim}", scheme.as_str());
                let want = tensor_from(j.get("logits").get(&key), &[4, classes]);
                let chip = ChipModel::ideal(b_pim);
                let mut rng = Rng::new(0);
                let got = net
                    .forward(
                        &x,
                        &ExecSpec::Pim { scheme, unit_channels: uc, chip: &chip },
                        &mut rng,
                    )
                    .unwrap();
                let diff = got.max_abs_diff(&want);
                // ideal chip is deterministic; drift comes only from f32 op
                // ordering in the digital layers. ADC tie flips can move one
                // logit by ~1 LSB-equivalent, so tolerance is loose-ish.
                assert!(diff < 5e-2, "{}/{key}: logits diff {diff}", src.model_file);
            }
        }
    }
}
