//! Chip-deployment study: take one trained PIM-QAT checkpoint and walk it
//! through increasingly realistic hardware — ideal ADCs, thermal noise,
//! measured-curve non-linearity, pre-calibration gain/offset variation —
//! showing where accuracy is lost and how much BN calibration (§3.4)
//! recovers at each stage.
//!
//!     cargo run --release --example chip_deploy
//!
//! Runs on the native backend by default (no artifacts needed).

use pim_qat::chip::curves::{synthesize_bank_with, CurveStats};
use pim_qat::chip::{ChipModel, FaultProfile};
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::coordinator::SweepRunner;
use pim_qat::nn::ExecSpec;
use pim_qat::train::{self, network_from_ckpt};
use pim_qat::util::error::Result;
use pim_qat::util::rng::Rng;
use pim_qat::util::table::Table;

fn main() -> Result<()> {
    let backend = train::open_default_backend()?;
    let mut runner = SweepRunner::new(backend.as_ref());
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps: 300,
        train_size: 4096,
        test_size: 512,
        ..Default::default()
    };
    let out = runner.run(&job)?;
    let (train_ds, test_ds) = {
        let pair = runner.datasets(&job)?;
        (pair.0.clone(), pair.1.clone())
    };
    println!("checkpoint: software accuracy {:.1}%\n", out.software_acc);

    // hardware realism ladder
    let uncal = {
        let bank = synthesize_bank_with(7, 32, 0xA7, CurveStats::uncalibrated());
        ChipModel { b_pim: 7, noise_lsb: 0.35, bank: Some(bank), unit_out: 8, faults: None }
    };
    let ladder: Vec<(&str, ChipModel)> = vec![
        ("ideal 7-bit ADC", ChipModel::ideal(7)),
        ("+ thermal noise 0.35 LSB", ChipModel::ideal(7).with_noise(0.35)),
        ("+ measured-curve INL", ChipModel::real(0xC819).with_noise(0.35)),
        ("+ uncalibrated gain/offset", uncal),
        (
            "+ field faults (moderate)",
            ChipModel::real(0xC819)
                .with_noise(0.35)
                .with_faults(FaultProfile::moderate()),
        ),
    ];

    let mut t = Table::new(&["Hardware", "no BN calib", "with BN calib"]);
    for (label, chip) in &ladder {
        let exec = ExecSpec::Pim {
            scheme: job.scheme,
            unit_channels: job.unit_channels,
            chip,
        };
        let mut rng = Rng::new(1);
        let net = network_from_ckpt(runner.manifest(), &out.ckpt)?;
        let raw = net.evaluate(&test_ds, 32, &exec, &mut rng)?;
        let mut net = network_from_ckpt(runner.manifest(), &out.ckpt)?;
        net.calibrate_bn(&train_ds, 32, 4, &exec, &mut rng)?;
        let cal = net.evaluate(&test_ds, 32, &exec, &mut rng)?;
        t.row(&[label.to_string(), format!("{raw:.1}"), format!("{cal:.1}")]);
    }
    println!("{}", t.render());
    println!("expected shape: each non-ideality costs accuracy; BN calibration recovers most of it, including the gain/offset collapse (paper Fig. A6, Table A4)");
    Ok(())
}
