//! Quickstart: open the default (native, zero-dependency) backend, train a
//! small PIM-QAT model for a few steps, and deploy it on the simulated
//! 7-bit chip.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed: the native backend trains with the hand-rolled
//! forward/backward and the built-in model registry.  With `make
//! artifacts` and `--features pjrt`, the same code runs through the
//! AOT-lowered HLO executables instead (`PIM_QAT_BACKEND=pjrt`).

use pim_qat::chip::ChipModel;
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::synth;
use pim_qat::nn::ExecSpec;
use pim_qat::train::{self, Backend};
use pim_qat::util::error::Result;
use pim_qat::util::rng::Rng;

fn main() -> Result<()> {
    // 1. open the training backend (native unless PIM_QAT_BACKEND says else)
    let backend = train::open_default_backend()?;
    println!("backend: {} — {}", backend.name(), backend.platform());

    // 2. a small PIM-QAT training job: bit-serial scheme, N = 72, b_PIM = 7
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps: 120,
        train_size: 1024,
        test_size: 256,
        ..Default::default()
    };
    let train_ds = synth::generate(16, 10, job.train_size, 1);
    let test_ds = synth::generate(16, 10, job.test_size, 2);

    println!("training {} for {} steps ...", job.artifact_name(), job.steps);
    let res = backend.train_job(&job, &train_ds, &test_ds, 20)?;
    for l in &res.history {
        println!("  step {:>4} loss {:.3} batch-acc {:.1}%", l.step, l.loss, l.acc);
    }
    println!("software (digital) test accuracy: {:.1}%", res.software_acc);

    // 3. deploy the checkpoint on the chip simulator: ideal and real
    let net = train::network_from_ckpt(backend.manifest(), &res.ckpt)?;
    let mut rng = Rng::new(0);
    for (label, chip) in [
        ("ideal 7-bit chip", ChipModel::ideal(7)),
        ("real chip (curves + 0.35 LSB noise)", ChipModel::real(0xC819).with_noise(0.35)),
    ] {
        let acc = net.evaluate(
            &test_ds,
            32,
            &ExecSpec::Pim { scheme: job.scheme, unit_channels: job.unit_channels, chip: &chip },
            &mut rng,
        )?;
        println!("{label}: {acc:.1}%");
    }

    // 4. BN calibration (§3.4) recovers real-chip accuracy
    let mut net = train::network_from_ckpt(backend.manifest(), &res.ckpt)?;
    let chip = ChipModel::real(0xC819).with_noise(0.35);
    let exec = ExecSpec::Pim { scheme: job.scheme, unit_channels: job.unit_channels, chip: &chip };
    net.calibrate_bn(&train_ds, 32, 4, &exec, &mut rng)?;
    let acc = net.evaluate(&test_ds, 32, &exec, &mut rng)?;
    println!("real chip after BN calibration: {acc:.1}%");
    Ok(())
}
