//! Quickstart: load the runtime, train a small PIM-QAT model for a few
//! steps, and deploy it on the simulated 7-bit chip.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This touches every layer of the stack: HLO artifacts through PJRT (L2/L1
//! lowered), the rust training loop, and the chip simulator.

use pim_qat::chip::ChipModel;
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::synth;
use pim_qat::nn::ExecSpec;
use pim_qat::runtime;
use pim_qat::train;
use pim_qat::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. open the artifacts produced by `make artifacts`
    let rt = runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. a small PIM-QAT training job: bit-serial scheme, N = 72, b_PIM = 7
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps: 120,
        train_size: 1024,
        test_size: 256,
        ..Default::default()
    };
    let train_ds = synth::generate(16, 10, job.train_size, 1);
    let test_ds = synth::generate(16, 10, job.test_size, 2);

    println!("training {} for {} steps ...", job.artifact_name(), job.steps);
    let res = train::run_job(&rt, &job, &train_ds, &test_ds, 20)?;
    for l in &res.history {
        println!("  step {:>4} loss {:.3} batch-acc {:.1}%", l.step, l.loss, l.acc);
    }
    println!("software (digital) test accuracy: {:.1}%", res.software_acc);

    // 3. deploy the checkpoint on the chip simulator: ideal and real
    let net = train::network_from_ckpt(&rt, &res.ckpt)?;
    let mut rng = Rng::new(0);
    for (label, chip) in [
        ("ideal 7-bit chip", ChipModel::ideal(7)),
        ("real chip (curves + 0.35 LSB noise)", ChipModel::real(0xC819).with_noise(0.35)),
    ] {
        let acc = net.evaluate(
            &test_ds,
            32,
            &ExecSpec::Pim { scheme: job.scheme, unit_channels: job.unit_channels, chip: &chip },
            &mut rng,
        )?;
        println!("{label}: {acc:.1}%");
    }

    // 4. BN calibration (§3.4) recovers real-chip accuracy
    let mut net = train::network_from_ckpt(&rt, &res.ckpt)?;
    let chip = ChipModel::real(0xC819).with_noise(0.35);
    let exec = ExecSpec::Pim { scheme: job.scheme, unit_channels: job.unit_channels, chip: &chip };
    net.calibrate_bn(&train_ds, 32, 4, &exec, &mut rng)?;
    let acc = net.evaluate(&test_ds, 32, &exec, &mut rng)?;
    println!("real chip after BN calibration: {acc:.1}%");
    Ok(())
}
