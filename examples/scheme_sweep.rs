//! Decomposition-scheme sweep (paper Fig. 5 in miniature): train PIM-QAT
//! under all three PIM decomposition schemes and compare their robustness
//! to ADC resolution, via the coordinator's grid machinery.
//!
//!     cargo run --release --example scheme_sweep
//!
//! Runs on the native backend by default (no artifacts needed).

use pim_qat::chip::ChipModel;
use pim_qat::config::{JobConfig, Scheme};
use pim_qat::coordinator::{sweep, SweepRunner};
use pim_qat::nn::ExecSpec;
use pim_qat::train::{self, network_from_ckpt};
use pim_qat::util::error::{anyhow, Result};
use pim_qat::util::rng::Rng;
use pim_qat::util::table::Table;

fn main() -> Result<()> {
    let backend = train::open_default_backend()?;
    let mut runner = SweepRunner::new(backend.as_ref());
    let base = JobConfig {
        model: "tiny".into(),
        steps: 300,
        train_size: 4096,
        test_size: 512,
        ..Default::default()
    };

    // the native scheme runs at unit channel 1 (N = 9), the other two at 8
    // (N = 72) — same geometry as the paper's Table 3 / Fig. 5 setup.
    let mut jobs = Vec::new();
    for scheme in Scheme::ALL {
        let uc = if scheme == Scheme::Native { 1 } else { 8 };
        for grid_job in
            sweep::parse_grid(&base, &format!("scheme={scheme};uc={uc};b_pim=4,5,7"))
                .map_err(|e| anyhow!(e))?
        {
            jobs.push(grid_job);
        }
    }
    println!("sweep: {} jobs (cached jobs are reused)", jobs.len());

    let mut t = Table::new(&["scheme", "b_PIM", "software", "ideal chip", "chip + 0.5 LSB noise"]);
    for job in &jobs {
        let out = runner.run(job)?;
        let test = {
            let pair = runner.datasets(job)?;
            pair.1.clone()
        };
        let mut accs = Vec::new();
        for noise in [0.0f32, 0.5] {
            let chip = ChipModel::ideal(job.b_pim_train).with_noise(noise);
            let mut net = network_from_ckpt(runner.manifest(), &out.ckpt)?;
            let exec = ExecSpec::Pim {
                scheme: job.scheme,
                unit_channels: job.unit_channels,
                chip: &chip,
            };
            let mut rng = Rng::new(2);
            if noise > 0.0 {
                let train = {
                    let pair = runner.datasets(job)?;
                    pair.0.clone()
                };
                net.calibrate_bn(&train, 32, 4, &exec, &mut rng)?;
            }
            accs.push(net.evaluate(&test, 32, &exec, &mut rng)?);
        }
        t.row(&[
            job.scheme.to_string(),
            job.b_pim_train.to_string(),
            format!("{:.1}", out.software_acc),
            format!("{:.1}", accs[0]),
            format!("{:.1}", accs[1]),
        ]);
    }
    println!("{}", t.render());
    println!("expected shape: all three schemes train to comparable accuracy at 7 bits; native (small N) is gentlest at low resolution, matching Fig. 5");
    Ok(())
}
