//! End-to-end training driver (the repo's E2E validation example): trains
//! the paper's method and its two baselines on the full synthetic corpus,
//! logs loss curves, and reports the Table-3-style comparison on ideal PIM
//! chips at several resolutions.  Takes a few minutes on one core.
//!
//!     cargo run --release --example train_pim_qat [-- steps]
//!
//! Runs on the native backend by default (no artifacts needed).  The run
//! is recorded in EXPERIMENTS.md §End-to-end.

use pim_qat::chip::ChipModel;
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::coordinator::SweepRunner;
use pim_qat::nn::ExecSpec;
use pim_qat::train::{self, network_from_ckpt};
use pim_qat::util::error::Result;
use pim_qat::util::rng::Rng;
use pim_qat::util::table::Table;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let backend = train::open_default_backend()?;
    let mut runner = SweepRunner::new(backend.as_ref());

    let base = JobConfig {
        model: "tiny".into(),
        steps,
        train_size: 4096,
        test_size: 512,
        ..Default::default()
    };

    // --- train the three methods (bit-serial, b_PIM = 5: a regime where the
    // baseline visibly degrades)
    let b_pim = 5u32;
    let mut jobs = Vec::new();
    for mode in [Mode::Baseline, Mode::Ams, Mode::Ours] {
        let mut j = base.clone();
        j.mode = mode;
        j.scheme = if mode == Mode::Ams { Scheme::Native } else { Scheme::BitSerial };
        j.unit_channels = if mode == Mode::Ams { 1 } else { 8 };
        j.b_pim_train = b_pim;
        jobs.push(j);
    }

    let mut results = Vec::new();
    for job in &jobs {
        let out = runner.run(job)?;
        println!(
            "\n=== {} — loss curve ===",
            job.artifact_name()
        );
        for l in &out.history {
            println!("  step {:>4} lr {:<6} loss {:<8.4} batch-acc {:.1}%", l.step, l.lr, l.loss, l.acc);
        }
        results.push(out);
    }

    // --- deploy on ideal chips of decreasing resolution
    let mut t = Table::new(&["Method", "software", "b=7 chip", "b=5 chip", "b=4 chip"]);
    for (job, out) in jobs.iter().zip(&results) {
        let (scheme, uc) = (job.scheme, job.unit_channels);
        let mut accs = Vec::new();
        for b in [7u32, 5, 4] {
            let chip = ChipModel::ideal(b);
            let net = network_from_ckpt(runner.manifest(), &out.ckpt)?;
            let mut rng = Rng::new(0);
            let test = {
                let pair = runner.datasets(job)?;
                pair.1.clone()
            };
            let acc = net.evaluate(
                &test,
                32,
                &ExecSpec::Pim { scheme, unit_channels: uc, chip: &chip },
                &mut rng,
            )?;
            accs.push(acc);
        }
        t.row(&[
            format!("{}", job.mode),
            format!("{:.1}", out.software_acc),
            format!("{:.1}", accs[0]),
            format!("{:.1}", accs[1]),
            format!("{:.1}", accs[2]),
        ]);
    }
    println!("\n{}", t.render());
    println!("expected shape: ours holds its accuracy on low-resolution chips; the baseline collapses (paper Tables 3/A2)");
    Ok(())
}
